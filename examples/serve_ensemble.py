"""Live ensemble serving over a training run — the train→serve pipeline.

End-to-end demo of ``repro.serve`` (docs/serving.md):

1. **Train with checkpoints** — an ``AveragingRun`` (rounds=2, SGD
   epochs) starts with a ``CheckpointConfig`` and is preempted right
   after its round-0 checkpoint is durable
   (``repro.core.faults.run_to_crash`` — the injected-crash stand-in for
   a spot reclaim).
2. **Serve the checkpoint** — a ``BucketedScorer`` (one XLA compile per
   bucket, ever) over round 0's member snapshot goes behind an
   ``EnsembleServer`` (continuous batching under a latency SLO) with a
   ``CheckpointWatcher`` polling the same directory; an open-loop
   traffic thread keeps single-image requests flowing.
3. **Training resumes, the endpoint hot-swaps** — ``AveragingRun.resume``
   finishes round 1 (bit-identical to the uninterrupted run) and writes
   ``round-1.npz``; the watcher picks it up and swaps the serving
   weights BETWEEN batches: zero dropped requests, zero recompiles, and
   post-swap predictions bit-equal to scoring the new checkpoint
   directly (asserted).

  PYTHONPATH=src python examples/serve_ensemble.py          # full demo
  PYTHONPATH=src python examples/serve_ensemble.py --smoke  # CI config
"""
import argparse
import tempfile
import threading
import time

import numpy as np

import jax

from repro.configs.base import get_reduced_config, replace
from repro.checkpoint import run_state
from repro.core import faults
from repro.core.runner import AveragingRun, MapConfig, ReduceConfig
from repro.data.partition import partition_iid
from repro.data.synthetic import make_extended_mnist
from repro.optim.schedules import dynamic_paper
from repro.serve import (BucketedScorer, CheckpointWatcher, EnsembleServer,
                         ServeConfig)


def main(smoke: bool = False):
    cfg = replace(get_reduced_config("cnn_elm_6c12c"), elm_lambda=1.0)
    ds = make_extended_mnist(n_per_class=30 if smoke else 80, seed=0)
    train, test = ds.split(n_test=60 if smoke else 200)
    k = 3
    parts = partition_iid(train.x, train.y, k)
    key = jax.random.PRNGKey(0)
    run = AveragingRun(
        cfg,
        MapConfig(epochs=2, lr_schedule=dynamic_paper(0.05), batch_size=50),
        ReduceConfig(rounds=2))
    ckpt_dir = tempfile.mkdtemp(prefix="serve_ensemble_")

    # -- 1. train until the round-0 checkpoint is durable, then "lose"
    #       the worker (spot reclaim) --------------------------------
    crashed = faults.run_to_crash(run, parts, key, ckpt_dir,
                                  unit="round", index=0)
    assert crashed and run_state.latest_ready_round(ckpt_dir) == 0
    print(f"train: preempted after round 0 (checkpoint in {ckpt_dir})")

    # -- 2. bring the endpoint up on what's on disk -------------------
    state0 = run_state.restore_round(ckpt_dir, 0)
    scorer = BucketedScorer(cfg, state0.members, max_batch=8)
    server = EnsembleServer(scorer, ServeConfig(max_batch=8,
                                                max_wait_ms=2.0)).start()
    watcher = CheckpointWatcher(ckpt_dir, server, poll_ms=10,
                                start_round=0).start()
    print(f"serve: k={scorer.k} ensemble up, buckets "
          f"{scorer.ladder.buckets}, {scorer.compile_count()} compiles")

    stop = threading.Event()
    traffic = []

    def offer_load():                      # open-loop background traffic
        i = 0
        while not stop.is_set():
            traffic.append(server.submit(test.x[i % len(test.x)]))
            i += 1
            time.sleep(0.002)

    th = threading.Thread(target=offer_load)
    th.start()

    # -- 3. training resumes on the same dir; the endpoint tracks it --
    t0 = time.perf_counter()
    run.resume(parts, key, ckpt_dir)
    swapped = watcher.wait_for_round(1, timeout_s=30)
    assert swapped, "watcher never saw round 1"
    t_swap = time.perf_counter() - t0
    time.sleep(0.05)                       # a few post-swap batches
    stop.set()
    th.join()

    # post-swap predictions must be BIT-EQUAL to scoring the new
    # checkpoint directly (same compiled program, same weights)
    probe = test.x[:7]
    via_server = np.stack(
        [f.result(10).member_scores for f in
         [server.submit(img) for img in probe]], axis=1)
    server.close()
    watcher.stop()
    direct = BucketedScorer(cfg, run_state.restore_round(ckpt_dir, 1).members,
                            max_batch=8).score_block(probe)
    assert np.array_equal(via_server, direct), \
        "post-swap serving diverged from the new checkpoint"

    stats = server.stats()
    failed = sum(1 for f in traffic if f.exception(timeout=10) is not None)
    assert failed == 0 and stats.failed == 0 and stats.dropped == 0
    scorer.assert_compile_budget()
    print(f"serve: resumed training wrote round 1; hot swap staged "
          f"{t_swap*1e3:.0f} ms after resume started")
    print(f"serve: {stats.completed} requests answered across the swap — "
          f"0 dropped, 0 failed, {stats.compile_count} compiles for "
          f"{len(scorer.ladder.buckets)} buckets (no recompile), "
          f"p50 {stats.percentile_ms(50):.1f} ms / "
          f"p99 {stats.percentile_ms(99):.1f} ms")
    print("serve: post-swap predictions bit-equal to the round-1 "
          "checkpoint — the endpoint now serves the resumed run's Reduce")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI config")
    main(smoke=ap.parse_args().smoke)
