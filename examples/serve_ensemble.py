"""Batched k-model ensemble serving through ``runner.Ensemble``.

The paper's Reduce collapses k members into ONE averaged model — but the k
trained members are also a free ensemble, and serving them naively costs k
host round-trips per request batch. ``Ensemble`` keeps the members in the
stacked layout the Map phase already produced and scores a request batch
under ALL k models in a single vmap dispatch, then combines by mean score
or majority vote.

This script trains k members (stacked Map phase, epochs=0: the closed-form
CNN-ELM), then compares

  * per-member accuracy via the one-model-at-a-time loop vs the batched
    surface (identical numbers, 1/k the dispatches),
  * the paper's weight-averaged model vs vote vs mean-score combination.

  PYTHONPATH=src python examples/serve_ensemble.py
"""
import time

import jax

from repro.configs.base import get_config
from repro.core.runner import (AveragingRun, Ensemble, MapConfig,
                               ReduceConfig, evaluate_model)
from repro.data.partition import partition_iid
from repro.data.synthetic import make_extended_mnist


def main():
    cfg = get_config("cnn_elm_6c12c")
    ds = make_extended_mnist(n_per_class=100)
    train, test = ds.split(n_test=600)
    k = 6

    result = AveragingRun(
        cfg,
        MapConfig(epochs=0, batch_size=200, backend="stacked"),
        ReduceConfig()).run(partition_iid(train.x, train.y, k),
                            jax.random.PRNGKey(0))
    print(f"trained k={k} members in {result.wall_time_s:.1f}s "
          f"({result.dispatches} dispatches)")

    ens = result.ensemble()                     # mean-score combination
    # the fair one-model-at-a-time baseline: k=1 ensembles built ONCE, so
    # the timed loop pays only per-model dispatches, not param restacking
    singles = [Ensemble.from_models(cfg, [m]) for m in result.members]
    # warm both jit caches so the comparison is steady-state serving cost
    # (k dispatches per batch vs one), not compile time
    singles[0].evaluate(test.x, test.y)
    ens.evaluate(test.x, test.y)
    t0 = time.perf_counter()
    loop_accs = [float(s.evaluate(test.x, test.y)[0]) for s in singles]
    t_loop = time.perf_counter() - t0
    t0 = time.perf_counter()
    batched_accs = ens.evaluate(test.x, test.y)
    t_batched = time.perf_counter() - t0

    print(f"\nper-member scoring, {len(test.x)} test rows:")
    print(f"  k-model Python loop: {t_loop*1e3:7.1f} ms  "
          f"accs={[f'{a:.4f}' for a in loop_accs]}")
    print(f"  batched Ensemble:    {t_batched*1e3:7.1f} ms  "
          f"accs={[f'{a:.4f}' for a in batched_accs]}  "
          f"({t_loop/t_batched:.1f}x, one dispatch per eval batch)")

    avg_acc = evaluate_model(cfg, result.averaged, test.x, test.y)
    vote = Ensemble(cfg, result.stacked, combine="vote")
    print("\ncombination modes:")
    print(f"  weight-averaged model (the paper's Reduce): {avg_acc:.4f}")
    print(f"  majority vote over {k} members:              "
          f"{vote.accuracy(test.x, test.y):.4f}")
    p_mean = ens.predict(test.x)                # one scoring pass, two metrics
    print(f"  mean-score over {k} members:                 "
          f"{ens.accuracy(test.x, test.y, preds=p_mean):.4f} "
          f"(kappa {ens.kappa_combined(test.x, test.y, preds=p_mean):.4f})")


if __name__ == "__main__":
    main()
