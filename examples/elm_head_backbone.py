"""ELM readout on a modern backbone — the paper's CNN-ELM integration
generalised (DESIGN.md §3).

A reduced HuBERT-style encoder plays the CNN's role (feature learner); the
ELM head is fit in closed form from E²LM sufficient statistics accumulated
over batches (Map), then the backbone is fine-tuned by back-propagating the
ELM least-squares error (Algorithm 2 lines 13-14) — no iterative head
training at any point.

  PYTHONPATH=src python examples/elm_head_backbone.py
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_reduced_config
from repro.core import elm, elm_head
from repro.models import api


def main():
    cfg = get_reduced_config("hubert_xlarge")
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)

    # synthetic frame-classification task: 8 latent classes, frames carry a
    # class-dependent bias the encoder can pick up
    rng = np.random.default_rng(0)
    C = 8
    class_emb = rng.normal(size=(C, 512)).astype(np.float32)

    def make_batch(seed):
        r = np.random.default_rng(seed)
        y = r.integers(0, C, size=(4, 64))
        frames = class_emb[y] + 0.3 * r.normal(size=(4, 64, 512))
        return {"frames": jnp.asarray(frames, jnp.bfloat16),
                "targets": jnp.asarray(y, jnp.int32)}

    feature_fn = functools.partial(
        lambda p, b: api.hidden_states(cfg, p, b))

    # ---- Map: accumulate U, V over batches ---------------------------------
    stats = None
    for i in range(8):
        stats = elm_head.accumulate_stats(feature_fn, params, make_batch(i),
                                          C, stats)
    beta = elm_head.solve(stats, lam=100.0)

    def acc(params, beta, seed):
        b = make_batch(seed)
        scores = elm_head.predict(feature_fn, params, beta, b)
        pred = jnp.argmax(scores, -1).reshape(b["targets"].shape)
        return float(jnp.mean((pred == b["targets"]).astype(jnp.float32)))

    print(f"ELM head, closed form (no head SGD): acc={acc(params, beta, 999):.3f}")

    # ---- Alg. 2 lines 13-14: fine-tune the backbone on the ELM error ------
    for step in range(5):
        params, loss = elm_head.finetune_step(
            feature_fn, params, beta, make_batch(100 + step), C, lr=1e-3)
        print(f"  finetune step {step}: elm loss={float(loss):.4f}")

    # re-solve the head after fine-tuning (paper's per-epoch re-solve)
    stats = None
    for i in range(8):
        stats = elm_head.accumulate_stats(feature_fn, params, make_batch(i),
                                          C, stats)
    beta = elm_head.solve(stats, lam=100.0)
    print(f"after backbone fine-tune + re-solve:  acc={acc(params, beta, 999):.3f}")


if __name__ == "__main__":
    main()
