"""Batched serving of an attention-free LM — the decode path that makes
``long_500k`` tractable (O(1)-in-sequence recurrent state).

  PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch import serve


def main():
    print("== rwkv6 (SSM state decode, the long_500k path) ==")
    serve.main(["--arch", "rwkv6_3b", "--reduced", "--batch", "4",
                "--prompt-len", "64", "--gen", "24"])
    print("\n== zamba2 hybrid (SSM + shared-attention ring buffer) ==")
    serve.main(["--arch", "zamba2_1p2b", "--reduced", "--batch", "2",
                "--prompt-len", "64", "--gen", "16"])


if __name__ == "__main__":
    main()
