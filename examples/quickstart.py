"""Quickstart — the paper in one script, on the composable runner API.

Distributed Averaging CNN-ELM (Algorithm 2) on the synthetic extended-MNIST
analogue: partition the data onto k 'machines', train a CNN-ELM on each
(Map, here the stacked vmap+scan fast path), average every weight (Reduce),
and compare against the monolithic model. The k members are scored through
the batched `Ensemble` surface — one device dispatch per eval batch for all
of them. Runs in ~1 minute on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.base import get_config
from repro.core import cnn_elm
from repro.core.runner import AveragingRun, MapConfig, ReduceConfig, evaluate_model
from repro.data.partition import partition_iid
from repro.data.synthetic import make_extended_mnist
from repro.models import cnn
from repro.optim.schedules import dynamic_paper


def main():
    cfg = get_config("cnn_elm_6c12c")          # the paper's Table-4 model
    ds = make_extended_mnist(n_per_class=100)  # 3x noise-extended, IID
    train, test = ds.split(n_test=500)

    k = 4
    parts = partition_iid(train.x, train.y, k)
    print(f"{len(train.x)} training examples -> {k} machines "
          f"x {len(parts[0].x)} examples")

    result = AveragingRun(
        cfg,
        MapConfig(epochs=1, lr_schedule=dynamic_paper(0.05), batch_size=200,
                  backend="stacked"),
        ReduceConfig(),                        # uniform mean, rounds=1
    ).run(parts, jax.random.PRNGKey(0))

    mono = cnn_elm.train_member(
        cfg, cnn.init_params(cfg, jax.random.PRNGKey(0)),
        partition_iid(train.x, train.y, 1)[0],
        epochs=1, lr_schedule=dynamic_paper(0.05), batch_size=200)

    print(f"monolithic (1 machine):  "
          f"{evaluate_model(cfg, mono, test.x, test.y):.4f}")
    member_accs = result.ensemble().evaluate(test.x, test.y)
    for i, acc in enumerate(member_accs):
        print(f"member {i+1}/{k}:            {acc:.4f}")
    print(f"weight-averaged ({k}):     "
          f"{evaluate_model(cfg, result.averaged, test.x, test.y):.4f}"
          f"  <- the paper's claim: ~= monolithic, at 1/k the wall time per"
          " machine")
    print(f"Map+Reduce telemetry: {result.dispatches} device dispatches, "
          f"{result.wall_time_s:.1f}s wall")


if __name__ == "__main__":
    main()
