"""Quickstart — the paper in one script.

Distributed Averaging CNN-ELM (Algorithm 2) on the synthetic extended-MNIST
analogue: partition the data onto k 'machines', train a CNN-ELM on each
(Map), average every weight (Reduce), and compare against the monolithic
model. Runs in ~1 minute on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.base import get_config
from repro.core import cnn_elm
from repro.data.partition import partition_iid
from repro.data.synthetic import make_extended_mnist
from repro.models import cnn
from repro.optim.schedules import dynamic_paper


def main():
    cfg = get_config("cnn_elm_6c12c")          # the paper's Table-4 model
    ds = make_extended_mnist(n_per_class=100)  # 3x noise-extended, IID
    train, test = ds.split(n_test=500)

    k = 4
    parts = partition_iid(train.x, train.y, k)
    print(f"{len(train.x)} training examples -> {k} machines "
          f"x {len(parts[0].x)} examples")

    members, averaged = cnn_elm.distributed_cnn_elm(
        cfg, parts, jax.random.PRNGKey(0),
        epochs=1, lr_schedule=dynamic_paper(0.05), batch_size=200)

    mono = cnn_elm.train_member(
        cfg, cnn.init_params(cfg, jax.random.PRNGKey(0)),
        partition_iid(train.x, train.y, 1)[0],
        epochs=1, lr_schedule=dynamic_paper(0.05), batch_size=200)

    print(f"monolithic (1 machine):  "
          f"{cnn_elm.evaluate(cfg, mono, test.x, test.y):.4f}")
    for i, m in enumerate(members):
        print(f"member {i+1}/{k}:            "
              f"{cnn_elm.evaluate(cfg, m, test.x, test.y):.4f}")
    print(f"weight-averaged ({k}):     "
          f"{cnn_elm.evaluate(cfg, averaged, test.x, test.y):.4f}  <- the paper's claim:"
          " ~= monolithic, at 1/k the wall time per machine")


if __name__ == "__main__":
    main()
